//! End-to-end coverage for the native CPU training backend — the tests the
//! acceptance criteria of ISSUE 2 name:
//!
//! * analytic gradients vs central finite differences,
//! * native scoring parity through the sharded scoring subsystem,
//! * a real Algorithm-1 run with zero AOT artifacts: uniform warmup,
//!   τ crossing τ_th, importance sampling switching on, and the
//!   upper-bound strategy beating uniform train loss at an equal step
//!   count on a separable synthetic task (fixed seed),
//! * the trainer-level bugfixes of the same issue (exact switch step,
//!   test-set tail evaluation) exercised through the native backend.

use anyhow::Result;
use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::score::{BackendScorer, ScoreBackend, ScoreKind};
use isample::runtime::{Backend, HostTensor, ModelState, NativeEngine, NativeModelSpec};
use xla::Literal;

/// Small, fast model used across these tests (any-batch native entries).
fn sep_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("sep", 32, 32, 4, 32, 64, vec![128, 256]));
    ne
}

/// Strongly separable task: most samples are near-noiseless prototypes
/// (learned in the first epochs — the "could be ignored" mass), a 12%
/// boundary tier keeps producing informative gradients. No outliers, so
/// every sample is learnable and importance sampling pays off cleanly.
fn sep_split() -> isample::data::Split<SyntheticImages> {
    SyntheticImages::builder(32, 4)
        .samples(2_048)
        .test_samples(256)
        .seed(11)
        .tiers(0.88, 0.12)
        .noise(0.03, 1.0)
        .split()
}

fn full_train_loss(ne: &NativeEngine, state: &ModelState, ds: &SyntheticImages) -> f64 {
    let idx: Vec<usize> = (0..ds.len()).collect();
    let (x, y) = ds.batch(&idx, 0);
    let (loss, _) = ne.fwd_scores(state, &x, &y).unwrap();
    loss.iter().map(|&l| l as f64).sum::<f64>() / loss.len() as f64
}

#[test]
fn upper_bound_beats_uniform_at_equal_step_count() {
    let ne = sep_engine();
    let split = sep_split();
    let steps = 400;
    let run = |cfg: TrainerConfig| {
        let mut tr = Trainer::new(&ne, cfg.with_steps(steps).with_seed(13)).unwrap();
        let report = tr.run(&split.train, None).unwrap();
        assert_eq!(report.steps, steps);
        (full_train_loss(&ne, &tr.state, &split.train), report)
    };
    let (uni_loss, _) = run(TrainerConfig::uniform("sep"));
    let (ub_loss, ub_report) =
        run(TrainerConfig::upper_bound("sep").with_presample(256).with_tau_th(1.1));

    // Algorithm 1 ran for real: uniform warmup first, then τ > τ_th.
    let switch = ub_report.is_switch_step.expect("importance sampling never switched on");
    assert!(switch >= 2, "step 1 must be a warmup step (switch at {switch})");
    assert!(!ub_report.log.rows.first().unwrap().is_active, "first logged row must be warmup");
    assert!(ub_report.log.rows.iter().any(|r| r.is_active), "no active rows logged");

    // The paper's core claim at equal steps: importance sampling reaches a
    // lower training loss than uniform SGD.
    println!("full-train loss: uniform {uni_loss:.5} vs upper-bound {ub_loss:.5} (IS@{switch})");
    assert!(
        ub_loss < uni_loss,
        "upper-bound ({ub_loss}) did not beat uniform ({uni_loss}) at {steps} steps"
    );
    assert!(ub_loss.is_finite() && uni_loss.is_finite());
}

#[test]
fn switch_step_is_recorded_exactly_not_log_quantized() {
    // τ ≥ 1 always, so τ_th = 0.5 makes the switch happen at step 2 — the
    // first step after the mandatory warmup observation. With
    // log_every = 10 the first *logged* active row is step 10; the report
    // must still carry the exact step.
    let ne = sep_engine();
    let split = sep_split();
    let mut cfg =
        TrainerConfig::upper_bound("sep").with_steps(30).with_presample(128).with_tau_th(0.5);
    cfg.log_every = 10;
    let mut tr = Trainer::new(&ne, cfg).unwrap();
    let report = tr.run(&split.train, None).unwrap();
    assert_eq!(report.is_switch_step, Some(2), "switch step must be exact");
    assert_eq!(report.log.is_switch_on_step(), Some(10), "rows are log_every-quantized");
}

#[test]
fn gradient_check_against_finite_differences() {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("tiny", 6, 5, 3, 8, 16, vec![16]));
    let state = ne.init_state("tiny", 3).unwrap();
    let n = 8;
    let mut x = HostTensor::zeros(vec![n, 6]);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i * 37 + 11) % 83) as f32 / 83.0 - 0.5;
    }
    let y: Vec<i32> = (0..n).map(|i| (i % 3) as i32).collect();
    let w = [0.5f32, 1.5, 1.0, 2.0, 0.3, 1.0, 0.7, 1.2];

    let (grads, loss0) = ne.weighted_grad(&state, &x, &y, &w).unwrap();
    assert!(loss0.is_finite());

    let weighted_loss = |params: &[Literal]| -> f64 {
        let s = ModelState {
            model: "tiny".to_string(),
            params: params.to_vec(),
            mom: vec![],
            step: 0,
        };
        let (loss, _) = ne.fwd_scores(&s, &x, &y).unwrap();
        loss.iter().zip(&w).map(|(&l, &wi)| l as f64 * wi as f64).sum::<f64>() / n as f64
    };
    let perturbed = |t: usize, idx: usize, eps: f32| -> Vec<Literal> {
        state
            .params
            .iter()
            .enumerate()
            .map(|(i, lit)| {
                let mut ht = HostTensor::from_literal(lit).unwrap();
                if i == t {
                    ht.data[idx] += eps;
                }
                ht.to_literal().unwrap()
            })
            .collect()
    };

    let eps = 1e-2f32;
    let mut checked = 0;
    for (t, g) in grads.iter().enumerate() {
        let gh = HostTensor::from_literal(g).unwrap();
        let len = gh.data.len();
        for &idx in &[0, len / 3, len - 1] {
            let up = weighted_loss(&perturbed(t, idx, eps));
            let down = weighted_loss(&perturbed(t, idx, -eps));
            let numeric = (up - down) / (2.0 * eps as f64);
            let analytic = gh.data[idx] as f64;
            assert!(
                (numeric - analytic).abs() < 2e-3 + 2e-2 * analytic.abs(),
                "tensor {t} idx {idx}: analytic {analytic} vs numeric {numeric}"
            );
            checked += 1;
        }
    }
    assert_eq!(checked, 12, "three entries per tensor across all four tensors");
}

#[test]
fn sharded_scoring_is_bit_identical_through_the_trainer_scorer() {
    // The exact scorer+backend combination the trainer's hot path uses.
    let ne = sep_engine();
    let state = ne.init_state("sep", 21).unwrap();
    let split = sep_split();
    let idx: Vec<usize> = (0..300).collect();
    let (x, y) = split.train.batch(&idx, 0);
    let scorer = BackendScorer { backend: &ne, state: &state };
    for kind in [ScoreKind::UpperBound, ScoreKind::Loss, ScoreKind::GradNorm] {
        let serial = ScoreBackend::Serial.score(&scorer, &x, &y, kind).unwrap();
        for workers in [2, 4, 11] {
            let par = ScoreBackend::from_workers(workers).score(&scorer, &x, &y, kind).unwrap();
            assert_eq!(par, serial, "workers={workers}");
        }
    }
}

/// A native backend whose `eval_metrics` only accepts one batch size —
/// the shape of a PJRT engine with a single baked eval artifact. Forces
/// `Trainer::evaluate` down its wrapped-tail path.
struct FixedEvalBatch<'a> {
    inner: &'a NativeEngine,
    eval_batch: usize,
}

impl Backend for FixedEvalBatch<'_> {
    fn name(&self) -> &'static str {
        "native-fixed-eval"
    }

    fn model_info(&self, model: &str) -> Result<&isample::runtime::ModelInfo> {
        self.inner.model_info(model)
    }

    fn supports(&self, model: &str, entry: &str, batch: usize) -> Result<bool> {
        if entry == "eval_metrics" {
            self.inner.model_info(model)?;
            return Ok(batch == self.eval_batch);
        }
        self.inner.supports(model, entry, batch)
    }

    fn prepare(&self, model: &str, entry: &str, batch: usize) -> Result<()> {
        if entry == "eval_metrics" {
            return Ok(());
        }
        self.inner.prepare(model, entry, batch)
    }

    fn init_state(&self, model: &str, seed: u64) -> Result<ModelState> {
        self.inner.init_state(model, seed)
    }

    fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<isample::runtime::engine::StepOutput> {
        self.inner.train_step(state, x, y, w, lr)
    }

    fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.inner.fwd_scores(state, x, y)
    }

    fn eval_metrics(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<(f64, i64)> {
        assert_eq!(x.shape[0], self.eval_batch, "partial shard reached a fixed-batch backend");
        self.inner.eval_metrics(state, x, y)
    }

    fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>> {
        self.inner.grad_norms(state, x, y)
    }

    fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)> {
        self.inner.grad(model, params, x, y)
    }

    fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)> {
        self.inner.weighted_grad(state, x, y, w)
    }
}

#[test]
fn evaluate_covers_the_test_set_tail() {
    // 100 samples with eval_batch 64: the seed dropped the 36-sample tail.
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("evm", 8, 8, 3, 16, 64, vec![64]));
    let test = SyntheticImages::builder(8, 3).samples(100).seed(5).build();

    // exact path (native supports any batch): must equal the one-shot
    // whole-set evaluation
    let mut tr = Trainer::new(&ne, TrainerConfig::uniform("evm")).unwrap();
    let (loss, err) = tr.evaluate(&test).unwrap();
    let idx: Vec<usize> = (0..test.len()).collect();
    let (x, y) = test.batch(&idx, 0);
    let (sum, correct) = ne.eval_metrics(&tr.state, &x, &y).unwrap();
    let (exact_loss, exact_err) = (sum / 100.0, 1.0 - correct as f64 / 100.0);
    assert!((loss - exact_loss).abs() < 1e-9, "{loss} vs {exact_loss}");
    assert!((err - exact_err).abs() < 1e-9, "{err} vs {exact_err}");

    // wrapped-weighted path (fixed-batch backend): approximate but close,
    // and every tail sample now counts toward `seen`
    let fixed = FixedEvalBatch { inner: &ne, eval_batch: 64 };
    let mut tr2 = Trainer::new(&fixed, TrainerConfig::uniform("evm")).unwrap();
    let (wloss, werr) = tr2.evaluate(&test).unwrap();
    assert!(
        (wloss - exact_loss).abs() < 0.25 * exact_loss.abs().max(0.1),
        "wrapped tail mean {wloss} too far from exact {exact_loss}"
    );
    assert!((0.0..=1.0).contains(&werr));
    assert!((werr - exact_err).abs() < 0.25, "wrapped err {werr} vs exact {exact_err}");

    // a test set smaller than the eval batch no longer bails
    let small = SyntheticImages::builder(8, 3).samples(40).seed(6).build();
    let (sloss, serr) = tr.evaluate(&small).unwrap();
    assert!(sloss.is_finite() && (0.0..=1.0).contains(&serr));
    let (wsloss, wserr) = tr2.evaluate(&small).unwrap();
    assert!(wsloss.is_finite() && (0.0..=1.0).contains(&wserr));
}

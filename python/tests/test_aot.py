"""AOT pipeline tests: RNG contract, lowering, manifest integrity."""

import json
import os

import numpy as np
import pytest

from compile import aot, rng as R
from compile import model as M


def test_splitmix64_known_vectors():
    # Reference vectors for seed 0 (cross-checked against the canonical
    # SplitMix64 from Vigna; rust/src/util/rng.rs pins the same values).
    r = R.SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F


def test_uniform_in_range_and_deterministic():
    r1, r2 = R.SplitMix64(123), R.SplitMix64(123)
    for _ in range(1000):
        u = r1.uniform()
        assert 0.0 <= u < 1.0
        assert u == r2.uniform()


def test_tensor_streams_differ():
    a = R.tensor_stream(42, 0).next_u64()
    b = R.tensor_stream(42, 1).next_u64()
    assert a != b


def test_glorot_bounds():
    t = R.init_tensor(7, 0, (64, 128), "glorot_uniform")
    a = (6.0 / (64 + 128)) ** 0.5
    assert t.shape == (64, 128)
    assert float(np.max(t)) <= a and float(np.min(t)) >= -a
    # not degenerate
    assert float(np.std(t)) > a / 4


def test_lstm_bias_forget_gate():
    t = R.init_tensor(7, 3, (256,), "lstm_bias")
    h = 64
    assert np.all(t[h : 2 * h] == 1.0)
    assert np.all(t[:h] == 0.0) and np.all(t[2 * h :] == 0.0)


def test_scaled_normal_moments():
    t = R.init_tensor(7, 1, (3, 3, 16, 32), "scaled_normal")
    fan_in = 3 * 3 * 16
    std = (2.0 / fan_in) ** 0.5
    assert abs(float(np.std(t)) - std) < std * 0.15
    assert abs(float(np.mean(t))) < std * 0.1


def test_synth_inputs_deterministic_formula():
    m = M.MODELS["mlp10"]
    x, y = aot.synth_inputs(m, 8)
    # spot-check the exact formula rust reimplements
    assert x[0, 0] == np.float32(0.0 / 97.0 - 0.5)
    assert x[0, 5] == np.float32(5 % 97 / 97.0 - 0.5)
    i, j = 3, 17
    assert x[i, j] == np.float32(((i * 64 + j) % 97) / 97.0 - 0.5)
    assert y[3] == 3 and y[7] == 7


def test_lowering_produces_parseable_hlo_text():
    m = M.MODELS["mlp10"]
    lowered, specs = aot.lower_entry(m, "fwd_scores", 8)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # one HLO parameter per flat arg in the ENTRY computation (nested
    # computations — e.g. the pallas interpret while-loop — have their own)
    entry = text[text.index("ENTRY") :]
    n_params = sum(1 for line in entry.splitlines() if " parameter(" in line)
    assert n_params == len(specs)


def test_selfcheck_is_reproducible():
    m = M.MODELS["mlp10"]
    a = aot.build_selfcheck(m)
    b = aot.build_selfcheck(m)
    assert a == b


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_consistency():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        man = json.load(f)
    assert man["format"] == "hlo-text"
    for name, info in man["models"].items():
        model = M.MODELS[name]
        assert info["num_classes"] == model.num_classes
        assert len(info["params"]) == len(model.params)
        for e in info["entries"]:
            fpath = os.path.join(os.path.dirname(path), e["file"])
            assert os.path.exists(fpath), f"missing artifact {e['file']}"
            # arity recorded in the manifest matches the specs
            _, specs_f = M.ENTRIES[e["entry"]]
            assert len(e["args"]) == len(specs_f(model, e["batch"]))
        sc = info["selfcheck"]
        assert len(sc["loss_head"]) == 4 and len(sc["param0_head"]) == 8
        assert np.isfinite(sc["mean_loss"])
        # a train step at lr=0.01 must not blow up the loss
        assert sc["mean_loss_after_step"] < sc["mean_loss"] * 1.5

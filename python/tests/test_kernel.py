"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal for the kernel layer: every test asserts
``allclose`` between the tiled/fused Pallas implementation and the obvious
reference, over swept shapes, block sizes, and adversarial inputs (huge
logits, one-hot-saturated rows, non-divisible batch/block combinations).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import last_layer as ll
from compile.kernels import ref


def make_case(b, c, scale=3.0, seed=0):
    rng = np.random.RandomState(seed)
    z = jnp.asarray(rng.randn(b, c).astype(np.float32) * scale)
    y = jnp.asarray(rng.randint(0, c, b).astype(np.int32))
    w = jnp.asarray(rng.rand(b).astype(np.float32) + 0.1)
    return z, y, w


def assert_fused_matches(z, y, **kw):
    l1, g1 = ll.fused_loss_scores(z, y, **kw)
    l2, g2 = ref.fused_loss_scores(z, y)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b", [1, 2, 16, 128, 129, 640])
@pytest.mark.parametrize("c", [2, 10, 100])
def test_fused_loss_scores_shapes(b, c):
    z, y, _ = make_case(b, c, seed=b * 1000 + c)
    assert_fused_matches(z, y)


@pytest.mark.parametrize("block_rows", [1, 7, 32, 128, 1024])
def test_fused_loss_scores_block_rows(block_rows):
    z, y, _ = make_case(200, 17, seed=3)
    assert_fused_matches(z, y, block_rows=block_rows)


def test_extreme_logits():
    # +-30 logits: softmax saturates; the logsumexp path must stay stable.
    z = jnp.asarray(np.array([[30.0, -30.0, 0.0], [-30.0, 30.0, 0.0]], np.float32))
    y = jnp.asarray(np.array([1, 1], np.int32))
    assert_fused_matches(z, y)
    l, g = ll.fused_loss_scores(z, y)
    assert np.all(np.isfinite(np.asarray(l)))
    assert np.all(np.isfinite(np.asarray(g)))


def test_perfectly_classified_sample_has_near_zero_score():
    # A sample with a huge true-class logit: loss ~ 0 AND ghat ~ 0 — this is
    # the property Alg. 1 exploits ("most samples could be ignored").
    z = jnp.asarray(np.array([[20.0, 0.0, 0.0]], np.float32))
    y = jnp.asarray(np.array([0], np.int32))
    l, g = ll.fused_loss_scores(z, y)
    assert float(l[0]) < 1e-6
    assert float(g[0]) < 1e-6


def test_score_upper_bound_range():
    # ||p - onehot||_2 <= sqrt(2) always (p on the simplex).
    z, y, _ = make_case(512, 10, scale=10.0, seed=7)
    _, g = ll.fused_loss_scores(z, y)
    assert float(jnp.max(g)) <= np.sqrt(2.0) + 1e-5
    assert float(jnp.min(g)) >= 0.0


def test_uniform_logits_score():
    # All-equal logits: p = 1/C, ghat = sqrt((1-1/C)^2 + (C-1)/C^2).
    c = 10
    z = jnp.zeros((4, c), jnp.float32)
    y = jnp.asarray(np.arange(4, dtype=np.int32))
    _, g = ll.fused_loss_scores(z, y)
    expect = np.sqrt((1 - 1 / c) ** 2 + (c - 1) / c**2)
    np.testing.assert_allclose(g, np.full(4, expect, np.float32), rtol=1e-5)


@pytest.mark.parametrize("b,c", [(1, 2), (64, 10), (129, 33)])
def test_weighted_xent_grad(b, c):
    z, y, w = make_case(b, c, seed=b + c)
    for gbar in (1.0, -0.5, 3.25):
        d1 = ll.weighted_xent_grad(z, y, w, jnp.full((1,), gbar, jnp.float32))
        d2 = ref.weighted_xent_grad(z, y, w, np.asarray([gbar], np.float32))
        np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-6)


def test_weighted_xent_grad_zero_weights():
    z, y, w = make_case(32, 5, seed=11)
    d = ll.weighted_xent_grad(z, y, jnp.zeros_like(w), jnp.ones((1,), jnp.float32))
    np.testing.assert_allclose(d, np.zeros_like(d), atol=0)


def test_grad_matches_autodiff_of_kernel_loss():
    # End-to-end: jax.grad through the custom_vjp wrapper equals the oracle
    # autodiff gradient (validates the defvjp wiring used in train_step).
    from compile.model import weighted_xent

    z, y, w = make_case(64, 10, seed=21)
    g1 = jax.grad(lambda zz: weighted_xent(zz, y, w))(z)
    g2 = jax.grad(lambda zz: ref.weighted_xent_mean(zz, y, w))(z)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, scales, block sizes
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=300),
    c=st.integers(min_value=2, max_value=64),
    scale=st.floats(min_value=0.01, max_value=20.0),
    block=st.sampled_from([8, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_fused_loss_scores(b, c, scale, block, seed):
    z, y, _ = make_case(b, c, scale=scale, seed=seed % 100000)
    l1, g1 = ll.fused_loss_scores(z, y, block_rows=block)
    l2, g2 = ref.fused_loss_scores(z, y)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=150),
    c=st.integers(min_value=2, max_value=32),
    gbar=st.floats(min_value=-5.0, max_value=5.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_weighted_grad(b, c, gbar, seed):
    z, y, w = make_case(b, c, seed=seed % 100000)
    d1 = ll.weighted_xent_grad(z, y, w, jnp.full((1,), gbar, jnp.float32))
    d2 = ref.weighted_xent_grad(z, y, w, np.asarray([gbar], np.float32))
    np.testing.assert_allclose(d1, d2, rtol=1e-3, atol=1e-5)

"""L2 correctness: entry points vs independent references.

Validates, for every model family:
  * ``fwd_scores``'s ghat equals the autodiff ``|| d loss / d logits ||_2``
    (the quantity Eq. 20 bounds with — exact for a linear last layer);
  * ``train_step`` equals a hand-rolled SGD+momentum+weight-decay update;
  * ``grad_norms`` equals per-sample ``jax.grad`` norms;
  * ``grad`` equals the mean autodiff gradient;
  * ``svrg_step`` algebra: g_cur - g_snap + mu;
  * ``eval_metrics`` counts and sums.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile.aot import init_params, synth_inputs
from compile.kernels import ref

SMALL = ["mlp10", "finetune", "lstm"]  # fast enough to test at full batch
ALL = ["mlp10", "cnn10", "cnn100", "finetune", "lstm"]


def setup(name, batch=None, seed=42):
    m = M.MODELS[name]
    b = batch or m.batch
    params = [jnp.asarray(p) for p in init_params(m, seed)]
    x, y = synth_inputs(m, b)
    return m, params, jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", ALL)
def test_fwd_scores_ghat_is_last_layer_grad_norm(name):
    m, params, x, y = setup(name, batch=16)
    loss, ghat = M.fwd_scores_fn(m)(*params, x, y)

    z = m.apply(params, x)
    # autodiff per-sample gradient of the loss w.r.t. logits
    def per_sample(zi, yi):
        g = jax.grad(lambda zz: ref.softmax_xent_loss(zz[None], yi[None])[0])(zi)
        return jnp.linalg.norm(g)

    expect = jax.vmap(per_sample)(z, y)
    np.testing.assert_allclose(ghat, expect, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(loss, ref.softmax_xent_loss(z, y), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", SMALL)
def test_train_step_matches_manual_sgd(name):
    m, params, x, y = setup(name)
    n = len(m.params)
    rng = np.random.RandomState(5)
    mom = [jnp.asarray(rng.randn(*p.shape).astype(np.float32) * 0.01) for p in m.params]
    w = jnp.asarray(rng.rand(m.batch).astype(np.float32) + 0.5)
    lr = np.float32(0.05)

    out = M.train_step_fn(m)(*params, *mom, x, y, w, lr)
    got_params, got_mom, got_loss = out[:n], out[n : 2 * n], out[2 * n]

    # manual update with pure-jnp loss
    def loss_fn(ps):
        z = m.apply(ps, x)
        return ref.weighted_xent_mean(z, y, w)

    loss, grads = jax.value_and_grad(loss_fn)(list(params))
    np.testing.assert_allclose(got_loss, loss, rtol=1e-5, atol=1e-6)
    for p, mo, g, gp, gm in zip(params, mom, grads, got_params, got_mom):
        if p.ndim > 1:
            g = g + M.WEIGHT_DECAY * p
        m2 = M.MOMENTUM * mo + g
        np.testing.assert_allclose(gm, m2, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(gp, p - lr * m2, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("name", ["mlp10"])
def test_grad_norms_matches_per_sample_grad(name):
    m, params, x, y = setup(name, batch=8)
    (got,) = M.grad_norms_fn(m)(*params, x, y)

    for i in range(8):
        def lf(ps):
            z = m.apply(ps, x[i : i + 1])
            return ref.softmax_xent_loss(z, y[i : i + 1])[0]

        gs = jax.grad(lf)(list(params))
        expect = float(jnp.sqrt(sum(jnp.vdot(g, g) for g in gs)))
        np.testing.assert_allclose(float(got[i]), expect, rtol=1e-4, atol=1e-6)


def test_upper_bound_tracks_grad_norm_after_training():
    # The paper's claim behind Fig. 2: on a *trained* network ghat is an
    # excellent (proportional) predictor of the true per-sample grad norm.
    # (At initialization all scores are near-uniform — also paper-consistent:
    # §3.3 "during the first iterations ... approximately equal norm".)
    m, params, x, y = setup("mlp10", batch=128)
    n = len(m.params)
    mom = [jnp.zeros(p.shape, jnp.float32) for p in m.params]
    w = jnp.ones(m.batch, jnp.float32)
    step = jax.jit(M.train_step_fn(m))
    params = list(params)
    for _ in range(200):
        out = step(*params, *mom, x, y, w, np.float32(0.1))
        params, mom = list(out[:n]), list(out[n : 2 * n])
    _, ghat = M.fwd_scores_fn(m)(*params, x, y)
    (gnorm,) = M.grad_norms_fn(m)(*params, x, y)
    ghat, gnorm = np.asarray(ghat), np.asarray(gnorm)
    # Spearman rank correlation, computed by hand (no scipy dependency).
    def ranks(v):
        r = np.empty_like(v)
        r[np.argsort(v)] = np.arange(len(v))
        return r

    rg, rn = ranks(ghat), ranks(gnorm)
    rho = np.corrcoef(rg, rn)[0, 1]
    assert rho > 0.7, f"rank correlation too low: {rho}"


@pytest.mark.parametrize("name", ["mlp10"])
def test_grad_entry(name):
    m, params, x, y = setup(name)
    n = len(m.params)
    out = M.grad_fn(m)(*params, x, y)
    grads, loss = out[:n], out[n]

    def lf(ps):
        z = m.apply(ps, x)
        return jnp.mean(ref.softmax_xent_loss(z, y))

    eloss, egrads = jax.value_and_grad(lf)(list(params))
    np.testing.assert_allclose(loss, eloss, rtol=1e-5)
    for g, e in zip(grads, egrads):
        np.testing.assert_allclose(g, e, rtol=1e-4, atol=1e-7)


def test_svrg_step_algebra():
    m, params, x, y = setup("mlp10")
    n = len(m.params)
    rng = np.random.RandomState(9)
    snap = [p + 0.01 * rng.randn(*p.shape).astype(np.float32) for p in params]
    mu = [jnp.asarray(rng.randn(*p.shape).astype(np.float32) * 0.001) for p in m.params]
    lr = np.float32(0.1)
    out = M.svrg_step_fn(m)(*params, *snap, *mu, x, y, lr)
    got_params = out[:n]

    def lf(ps):
        z = m.apply(ps, x)
        return jnp.mean(ref.softmax_xent_loss(z, y))

    g_cur = jax.grad(lf)(list(params))
    g_snap = jax.grad(lf)([jnp.asarray(s) for s in snap])
    for p, gc, gs, mm, gp in zip(params, g_cur, g_snap, mu, got_params):
        np.testing.assert_allclose(gp, p - lr * (gc - gs + mm), rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("name", ["mlp10", "lstm"])
def test_eval_metrics(name):
    m, params, x, y = setup(name, batch=m_batch(name))
    sum_loss, correct = M.eval_metrics_fn(m)(*params, x, y)
    z = m.apply(params, x)
    eloss = ref.softmax_xent_loss(z, y)
    np.testing.assert_allclose(sum_loss, jnp.sum(eloss), rtol=1e-5)
    ecorrect = int(jnp.sum((jnp.argmax(z, -1) == y).astype(jnp.int32)))
    assert int(correct) == ecorrect


def m_batch(name):
    return M.MODELS[name].eval_batch


def test_training_reduces_loss():
    # A few hundred steps of uniform SGD on the synthetic inputs must reduce
    # the loss — the L2 graph actually learns.
    m, params, x, y = setup("mlp10")
    n = len(m.params)
    mom = [jnp.zeros(p.shape, jnp.float32) for p in m.params]
    w = jnp.ones(m.batch, jnp.float32)
    step = jax.jit(M.train_step_fn(m))
    first = None
    params = list(params)
    for i in range(200):
        out = step(*params, *mom, x, y, w, np.float32(0.1))
        params, mom, loss = list(out[:n]), list(out[n : 2 * n]), float(out[2 * n])
        if first is None:
            first = loss
    assert loss < first * 0.5, f"loss did not drop: {first} -> {loss}"

"""Layer-2: JAX model definitions and AOT entry points.

Every numeric routine the rust coordinator executes at run time is defined
here, as a pure function over *flat positional arguments* (each parameter
tensor is its own argument, so the HLO parameter order is unambiguous), and
lowered once by ``aot.py`` to HLO text.

Models (one per paper task):
  * ``mlp10``    — small MLP, quickstart + fast tests                (§4.2 proxy)
  * ``cnn10``    — convnet, 10 classes  (CIFAR-10 stand-in)          (§4.2)
  * ``cnn100``   — convnet, 100 classes (CIFAR-100 stand-in; also the
                   Fig-1/Fig-2 ablation model)                       (§4.1, §4.2)
  * ``finetune`` — frozen-backbone features -> trainable head        (§4.3)
  * ``lstm``     — LSTM sequence classifier over T steps             (§4.4)

Entry points per model (see ``ENTRIES``):
  * ``fwd_scores(params, x, y) -> (loss[b], ghat[b])`` — single forward pass
    producing the per-sample loss and the Eq.-20 upper-bound score, through
    the L1 Pallas kernel.
  * ``train_step(params, mom, x, y, w, lr) -> (params', mom', loss)`` —
    weighted SGD+momentum step (Eq. 2); the backward pass goes through the
    L1 kernel's custom VJP.
  * ``grad_norms(params, x, y) -> gnorm[b]`` — *true* per-sample gradient
    norms (vmap-of-grad); the expensive oracle of Fig. 1/2.
  * ``grad(params, x, y) -> (grads..., loss)`` — mean minibatch gradient
    (SVRG/SCSG substrate).
  * ``svrg_step(params, snap, mu, x, y, lr) -> (params', loss)`` — one SVRG
    inner step: theta - lr * (g_i(theta) - g_i(snap) + mu).
  * ``eval_metrics(params, x, y) -> (sum_loss, correct)`` — test-set shards.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import last_layer as ll
from .kernels import ref

MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4


# ---------------------------------------------------------------------------
# Weighted cross-entropy with a Pallas forward AND backward (custom_vjp)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def weighted_xent(z, y, w):
    """(1/b) sum_i w_i * xent(z_i, y_i), fwd+bwd through the L1 kernels."""
    loss, _ = ll.fused_loss_scores(z, y)
    return jnp.mean(w * loss)


def _wx_fwd(z, y, w):
    loss, _ = ll.fused_loss_scores(z, y)
    return jnp.mean(w * loss), (z, y, w, loss)


def _wx_bwd(residuals, gbar):
    z, y, w, loss = residuals
    dz = ll.weighted_xent_grad(z, y, w, jnp.reshape(gbar, (1,)))
    dw = loss * gbar / z.shape[0]
    return dz, None, dw


weighted_xent.defvjp(_wx_fwd, _wx_bwd)


# ---------------------------------------------------------------------------
# Model definitions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    init: str  # rng.init_tensor kind


@dataclasses.dataclass(frozen=True)
class Model:
    """A model family: parameter specs + a pure apply(params, x) -> logits."""

    name: str
    params: Tuple[ParamSpec, ...]
    feature_dim: int  # per-sample input width (x is f32[b, feature_dim])
    num_classes: int
    apply: Callable  # (list[Array], Array[b, feature_dim]) -> Array[b, C]
    batch: int  # paper's training batch size b
    presample: Tuple[int, ...]  # presample sizes B to bake
    eval_batch: int


def _mlp_apply(dims: Sequence[int]):
    def apply(params, x):
        h = x
        n = len(dims) - 1
        for i in range(n):
            w, b = params[2 * i], params[2 * i + 1]
            h = h @ w + b
            if i + 1 < n:
                h = jax.nn.relu(h)
        return h

    return apply


def _mlp_params(dims: Sequence[int]) -> Tuple[ParamSpec, ...]:
    out = []
    for i in range(len(dims) - 1):
        out.append(ParamSpec(f"w{i}", (dims[i], dims[i + 1]), "glorot_uniform"))
        out.append(ParamSpec(f"b{i}", (dims[i + 1],), "zeros"))
    return tuple(out)


def _cnn_apply(side: int, chans: Sequence[int]):
    """conv3x3(c0) -> relu -> conv3x3/2(c1) -> relu -> conv3x3/2(c2) -> relu
    -> global-avg-pool -> dense. A wide-resnet-lite stand-in sized for CPU."""

    def apply(params, x):
        b = x.shape[0]
        h = x.reshape(b, side, side, 3)
        (k0, b0, k1, b1, k2, b2, wd, bd) = params
        dnums = ("NHWC", "HWIO", "NHWC")
        h = jax.lax.conv_general_dilated(
            h, k0, (1, 1), "SAME", dimension_numbers=dnums
        )
        h = jax.nn.relu(h + b0)
        h = jax.lax.conv_general_dilated(
            h, k1, (2, 2), "SAME", dimension_numbers=dnums
        )
        h = jax.nn.relu(h + b1)
        h = jax.lax.conv_general_dilated(
            h, k2, (2, 2), "SAME", dimension_numbers=dnums
        )
        h = jax.nn.relu(h + b2)
        h = jnp.mean(h, axis=(1, 2))  # global average pool -> (b, c2)
        return h @ wd + bd

    return apply


def _cnn_params(chans: Sequence[int], num_classes: int) -> Tuple[ParamSpec, ...]:
    c0, c1, c2 = chans
    return (
        ParamSpec("k0", (3, 3, 3, c0), "scaled_normal"),
        ParamSpec("cb0", (c0,), "zeros"),
        ParamSpec("k1", (3, 3, c0, c1), "scaled_normal"),
        ParamSpec("cb1", (c1,), "zeros"),
        ParamSpec("k2", (3, 3, c1, c2), "scaled_normal"),
        ParamSpec("cb2", (c2,), "zeros"),
        ParamSpec("wd", (c2, num_classes), "glorot_uniform"),
        ParamSpec("bd", (num_classes,), "zeros"),
    )


def _lstm_apply(hidden: int):
    def apply(params, x):
        wx, wh, bias, wo, bo = params
        b = x.shape[0]
        h0 = jnp.zeros((b, hidden), jnp.float32)
        c0 = jnp.zeros((b, hidden), jnp.float32)
        xs = x.T[:, :, None]  # (T, b, 1)

        def step(carry, xt):
            h, c = carry
            gates = xt @ wx + h @ wh + bias  # (b, 4H)
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), None

        (h, _), _ = jax.lax.scan(step, (h0, c0), xs)
        return h @ wo + bo

    return apply


def _lstm_params(hidden: int, num_classes: int) -> Tuple[ParamSpec, ...]:
    return (
        ParamSpec("wx", (1, 4 * hidden), "glorot_uniform"),
        ParamSpec("wh", (hidden, 4 * hidden), "glorot_uniform"),
        ParamSpec("bias", (4 * hidden,), "lstm_bias"),
        ParamSpec("wo", (hidden, num_classes), "glorot_uniform"),
        ParamSpec("bo", (num_classes,), "zeros"),
    )


def _models() -> Dict[str, Model]:
    side = 16
    models = {}
    models["mlp10"] = Model(
        name="mlp10",
        params=_mlp_params([64, 128, 128, 10]),
        feature_dim=64,
        num_classes=10,
        apply=_mlp_apply([64, 128, 128, 10]),
        batch=128,
        presample=(384, 640, 1024),
        eval_batch=512,
    )
    for nc in (10, 100):
        chans = (16, 32, 32)
        models[f"cnn{nc}"] = Model(
            name=f"cnn{nc}",
            params=_cnn_params(chans, nc),
            feature_dim=side * side * 3,
            num_classes=nc,
            apply=_cnn_apply(side, chans),
            batch=128,
            presample=(384, 640, 1024),
            eval_batch=512,
        )
    models["finetune"] = Model(
        name="finetune",
        params=_mlp_params([512, 256, 67]),
        feature_dim=512,
        num_classes=67,
        apply=_mlp_apply([512, 256, 67]),
        batch=16,
        presample=(48,),
        eval_batch=256,
    )
    t, hidden = 64, 64
    models["lstm"] = Model(
        name="lstm",
        params=_lstm_params(hidden, 10),
        feature_dim=t,
        num_classes=10,
        apply=_lstm_apply(hidden),
        batch=32,
        presample=(128,),
        eval_batch=256,
    )
    return models


MODELS: Dict[str, Model] = _models()


# ---------------------------------------------------------------------------
# Entry points (flat positional args, ready to lower)
# ---------------------------------------------------------------------------


def _param_specs(model: Model):
    return [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in model.params]


def _xy_specs(model: Model, batch: int):
    return [
        jax.ShapeDtypeStruct((batch, model.feature_dim), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]


def fwd_scores_fn(model: Model):
    n = len(model.params)

    def fn(*args):
        params, x, y = list(args[:n]), args[n], args[n + 1]
        z = model.apply(params, x)
        loss, ghat = ll.fused_loss_scores(z, y)
        return loss, ghat

    return fn


def fwd_scores_specs(model: Model, batch: int):
    return _param_specs(model) + _xy_specs(model, batch)


def train_step_fn(model: Model):
    """Weighted SGD+momentum step that ALSO returns per-sample loss + ghat.

    Single forward pass (``jax.vjp`` through ``model.apply``), with both L1
    kernels on the hot path: ``fused_loss_scores`` produces the per-sample
    loss and Eq.-20 score from the logits, ``weighted_xent_grad`` produces
    the logits cotangent. Returning the scores makes Algorithm 1 line 15
    ("we compute g_i for free since we have done the forward pass") *true*
    in the AOT artifact — the warmup phase needs no extra forward pass.
    """
    n = len(model.params)

    def fn(*args):
        params = list(args[:n])
        mom = list(args[n : 2 * n])
        x, y, w, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2], args[2 * n + 3]

        z, vjp = jax.vjp(lambda ps: model.apply(ps, x), params)
        loss_vec, ghat = ll.fused_loss_scores(z, y)
        loss = jnp.mean(w * loss_vec)
        dz = ll.weighted_xent_grad(z, y, w, jnp.ones((1,), jnp.float32))
        (grads,) = vjp(dz)

        new_params, new_mom = [], []
        for p, m, g in zip(params, mom, grads):
            # Weight decay on matrices/kernels only (Keras-style kernel L2).
            if p.ndim > 1:
                g = g + WEIGHT_DECAY * p
            m2 = MOMENTUM * m + g
            new_mom.append(m2)
            new_params.append(p - lr * m2)
        return (*new_params, *new_mom, loss, loss_vec, ghat)

    return fn


def train_step_specs(model: Model, batch: int):
    ps = _param_specs(model)
    return (
        ps
        + ps  # momentum slots
        + _xy_specs(model, batch)
        + [
            jax.ShapeDtypeStruct((batch,), jnp.float32),  # w
            jax.ShapeDtypeStruct((), jnp.float32),  # lr
        ]
    )


def grad_norms_fn(model: Model):
    n = len(model.params)

    def fn(*args):
        params, x, y = list(args[:n]), args[n], args[n + 1]

        def one(xi, yi):
            def lf(ps):
                z = model.apply(ps, xi[None])
                return ref.softmax_xent_loss(z, yi[None])[0]

            gs = jax.grad(lf)(params)
            sq = sum(jnp.vdot(g, g) for g in gs)
            return jnp.sqrt(sq)

        return (jax.vmap(one)(x, y),)

    return fn


def grad_norms_specs(model: Model, batch: int):
    return _param_specs(model) + _xy_specs(model, batch)


def weighted_grad_fn(model: Model):
    """Gradient of the re-weighted loss: d/dθ (1/b) Σ w_i loss_i.

    This is exactly the estimator a weighted SGD step applies (Eq. 2); the
    Fig-1 analysis uses it to measure ||G_b - G_B|| without touching the
    optimizer state.
    """
    n = len(model.params)

    def fn(*args):
        params = list(args[:n])
        x, y, w = args[n], args[n + 1], args[n + 2]

        def lf(ps):
            z = model.apply(ps, x)
            return weighted_xent(z, y, w)

        loss, gs = jax.value_and_grad(lf)(params)
        return (*gs, loss)

    return fn


def weighted_grad_specs(model: Model, batch: int):
    return (
        _param_specs(model)
        + _xy_specs(model, batch)
        + [jax.ShapeDtypeStruct((batch,), jnp.float32)]
    )


def grad_fn(model: Model):
    n = len(model.params)

    def fn(*args):
        params, x, y = list(args[:n]), args[n], args[n + 1]

        def lf(ps):
            z = model.apply(ps, x)
            return jnp.mean(ref.softmax_xent_loss(z, y))

        loss, gs = jax.value_and_grad(lf)(params)
        return (*gs, loss)

    return fn


def grad_specs(model: Model, batch: int):
    return _param_specs(model) + _xy_specs(model, batch)


def svrg_step_fn(model: Model):
    n = len(model.params)

    def fn(*args):
        params = list(args[:n])
        snap = list(args[n : 2 * n])
        mu = list(args[2 * n : 3 * n])
        x, y, lr = args[3 * n], args[3 * n + 1], args[3 * n + 2]

        def lf(ps):
            z = model.apply(ps, x)
            return jnp.mean(ref.softmax_xent_loss(z, y))

        loss, g_cur = jax.value_and_grad(lf)(params)
        g_snap = jax.grad(lf)(snap)
        new_params = [
            p - lr * (gc - gs + m) for p, gc, gs, m in zip(params, g_cur, g_snap, mu)
        ]
        return (*new_params, loss)

    return fn


def svrg_step_specs(model: Model, batch: int):
    ps = _param_specs(model)
    return (
        ps
        + ps  # snapshot params
        + ps  # mu = full gradient at snapshot
        + _xy_specs(model, batch)
        + [jax.ShapeDtypeStruct((), jnp.float32)]
    )


def eval_metrics_fn(model: Model):
    n = len(model.params)

    def fn(*args):
        params, x, y = list(args[:n]), args[n], args[n + 1]
        z = model.apply(params, x)
        loss = ref.softmax_xent_loss(z, y)
        correct = jnp.sum((jnp.argmax(z, axis=-1) == y).astype(jnp.int32))
        return jnp.sum(loss), correct

    return fn


def eval_metrics_specs(model: Model, batch: int):
    return _param_specs(model) + _xy_specs(model, batch)


def entry_batches(model: Model, entry: str) -> List[int]:
    """Which batch sizes to bake for each entry point."""
    b, evalb = model.batch, model.eval_batch
    pres = list(model.presample)
    if entry == "fwd_scores":
        # score at the training batch (warmup line 15 of Alg. 1 is "free")
        # and at every presample size.
        return sorted(set([b] + pres))
    if entry == "train_step":
        return [b]
    if entry == "grad_norms":
        # the Fig-1/2 oracle runs at the largest presample size; the small
        # training batch is baked too for integration tests.
        return sorted(set([b, max(pres)]))
    if entry == "grad":
        return [b]
    if entry == "weighted_grad":
        return [b]
    if entry == "svrg_step":
        return [b]
    if entry == "eval_metrics":
        return [evalb]
    raise ValueError(entry)


ENTRIES = {
    "fwd_scores": (fwd_scores_fn, fwd_scores_specs),
    "train_step": (train_step_fn, train_step_specs),
    "grad_norms": (grad_norms_fn, grad_norms_specs),
    "grad": (grad_fn, grad_specs),
    "weighted_grad": (weighted_grad_fn, weighted_grad_specs),
    "svrg_step": (svrg_step_fn, svrg_step_specs),
    "eval_metrics": (eval_metrics_fn, eval_metrics_specs),
}

"""SplitMix64 — the cross-language deterministic RNG.

Parameter initialization happens in **rust** at run time (Python is never on
the request path), but the AOT self-check (``manifest.json: selfcheck``)
needs Python to predict exactly which parameter values rust will generate.
Both sides therefore implement the same SplitMix64 stream:

    state += 0x9E3779B97F4A7C15
    z = state
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9
    z = (z ^ (z >> 27)) * 0x94D049BB133111EB
    z = z ^ (z >> 31)

``uniform()`` maps the top 53 bits to f64 in [0, 1). Tensor ``i`` of a model
uses the stream seeded with ``seed + i * GOLDEN`` (documented in the
manifest); draws are row-major over the tensor.

The rust twin is ``rust/src/util/rng.rs``; ``rust/tests`` cross-check the
first draws against vectors baked into the manifest.
"""

from __future__ import annotations

MASK64 = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15


class SplitMix64:
    """Exact-u64 SplitMix64, bit-identical to the rust implementation."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + GOLDEN) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def uniform(self) -> float:
        """f64 in [0, 1): top 53 bits / 2^53 (same expression as rust)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform_range(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.uniform()


def tensor_stream(seed: int, tensor_index: int) -> SplitMix64:
    """The per-tensor stream: independent, order-insensitive across tensors."""
    return SplitMix64((seed + tensor_index * GOLDEN) & MASK64)


def init_tensor(seed: int, tensor_index: int, shape, kind: str):
    """Generate one parameter tensor exactly as rust's ParamInit does.

    kinds:
      zeros          — all zeros (biases, momentum slots)
      glorot_uniform — U(-a, a), a = sqrt(6 / (fan_in + fan_out))
      lstm_bias      — zeros with the forget-gate quarter set to 1.0
      scaled_normal  — N(0, 2/fan_in) via Box-Muller (conv kernels)
    """
    import numpy as np

    n = 1
    for d in shape:
        n *= d
    if kind == "zeros":
        return np.zeros(shape, dtype=np.float32)
    if kind == "lstm_bias":
        # shape = (4H,): gate order [i, f, g, o]; forget-gate biased to 1.
        out = np.zeros(shape, dtype=np.float32)
        h = shape[0] // 4
        out[h : 2 * h] = 1.0
        return out

    rng = tensor_stream(seed, tensor_index)
    if kind == "glorot_uniform":
        fan_in, fan_out = _fans(shape)
        a = (6.0 / (fan_in + fan_out)) ** 0.5
        vals = [rng.uniform_range(-a, a) for _ in range(n)]
        return np.asarray(vals, dtype=np.float32).reshape(shape)
    if kind == "scaled_normal":
        import math

        fan_in, _ = _fans(shape)
        std = math.sqrt(2.0 / fan_in)
        vals = []
        while len(vals) < n:
            # Box-Muller, same draw order as rust (u1 then u2, both outputs used).
            u1 = max(rng.uniform(), 1e-12)
            u2 = rng.uniform()
            r = math.sqrt(-2.0 * math.log(u1))
            vals.append(r * math.cos(2.0 * math.pi * u2) * std)
            vals.append(r * math.sin(2.0 * math.pi * u2) * std)
        return np.asarray(vals[:n], dtype=np.float32).reshape(shape)
    raise ValueError(f"unknown init kind {kind!r}")


def _fans(shape):
    """fan_in/fan_out, matching rust: conv HWIO uses receptive-field product."""
    if len(shape) == 2:
        return shape[0], shape[1]
    if len(shape) == 4:  # HWIO
        rf = shape[0] * shape[1]
        return shape[2] * rf, shape[3] * rf
    n = 1
    for d in shape:
        n *= d
    return n, n

"""AOT compiler: lower every (model, entry, batch) to HLO text + manifest.

This is the *only* Python that ever runs: ``make artifacts`` invokes it once,
it writes ``artifacts/*.hlo.txt`` plus ``artifacts/manifest.json``, and the
rust coordinator is self-contained from then on.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest tells rust everything it needs to run without Python:
  * per-model parameter tree (name/shape/init kind) + the SplitMix64 seeding
    discipline (rng.py) so rust can initialize parameters bit-identically;
  * per-artifact arg/output arity and shapes;
  * a ``selfcheck`` block: deterministic inputs (formula-generated) and
    expected outputs so rust integration tests can assert numerics
    end-to-end against what Python computed at build time.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from . import model as M
from . import rng as R

SELFCHECK_SEED = 42

# Not every entry is needed for every model (see DESIGN.md §3):
#   fig1/fig2 oracle (grad_norms)      -> cnn100 (paper's ablation net) + mlp10 (tests)
#   SVRG substrate (grad, svrg_step)   -> fig6 runs the fig3 image setup + mlp10 (tests)
ENTRIES_FOR_MODEL = {
    "mlp10": [
        "fwd_scores", "train_step", "grad_norms", "grad", "weighted_grad",
        "svrg_step", "eval_metrics",
    ],
    "cnn10": ["fwd_scores", "train_step", "grad", "svrg_step", "eval_metrics"],
    "cnn100": [
        "fwd_scores", "train_step", "grad_norms", "grad", "weighted_grad",
        "svrg_step", "eval_metrics",
    ],
    "finetune": ["fwd_scores", "train_step", "eval_metrics"],
    "lstm": ["fwd_scores", "train_step", "eval_metrics"],
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def synth_inputs(model: M.Model, batch: int):
    """Deterministic integer-math inputs shared with rust (selfcheck tests).

    x[i, j] = ((i * D + j) % 97) / 97 - 0.5 ;  y[i] = i % C
    """
    d = model.feature_dim
    idx = np.arange(batch * d, dtype=np.int64).reshape(batch, d)
    x = ((idx % 97).astype(np.float32) / 97.0) - 0.5
    y = (np.arange(batch, dtype=np.int64) % model.num_classes).astype(np.int32)
    return x, y


def init_params(model: M.Model, seed: int):
    return [
        R.init_tensor(seed, i, p.shape, p.init) for i, p in enumerate(model.params)
    ]


def build_selfcheck(model: M.Model) -> dict:
    """Run fwd_scores + one train_step in python; bake expected numbers."""
    params = init_params(model, SELFCHECK_SEED)
    x, y = synth_inputs(model, model.batch)
    fn = M.fwd_scores_fn(model)
    loss, ghat = fn(*params, x, y)
    loss = np.asarray(loss)
    ghat = np.asarray(ghat)

    # One uniform train step (w = 1, lr = 0.01), then the mean loss again —
    # checks the whole train path including momentum/weight-decay plumbing.
    mom = [np.zeros(p.shape, np.float32) for p in model.params]
    w = np.ones(model.batch, np.float32)
    step = M.train_step_fn(model)
    out = step(*params, *mom, x, y, w, np.float32(0.01))
    n = len(model.params)
    new_params = [np.asarray(t) for t in out[:n]]
    step_loss = float(out[2 * n])
    loss2, _ = fn(*new_params, x, y)
    return {
        "seed": SELFCHECK_SEED,
        "batch": model.batch,
        "loss_head": [float(v) for v in loss[:4]],
        "ghat_head": [float(v) for v in ghat[:4]],
        "mean_loss": float(loss.mean()),
        "step_loss": step_loss,
        "mean_loss_after_step": float(np.asarray(loss2).mean()),
        # first values of the first weight tensor, to pin the RNG contract
        "param0_head": [float(v) for v in np.asarray(params[0]).reshape(-1)[:8]],
    }


def lower_entry(model: M.Model, entry: str, batch: int):
    fn_f, specs_f = M.ENTRIES[entry]
    fn = fn_f(model)
    specs = specs_f(model, batch)
    return jax.jit(fn).lower(*specs), specs


def spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--models", default="all", help="comma list or 'all'")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    out_dir = args.out
    if out_dir.endswith(".hlo.txt"):  # tolerate Makefile-style file target
        out_dir = os.path.dirname(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    names = (
        list(ENTRIES_FOR_MODEL) if args.models == "all" else args.models.split(",")
    )
    manifest = {
        "version": 1,
        "format": "hlo-text",
        "rng": {
            "algo": "splitmix64",
            "stream": "seed + tensor_index * 0x9E3779B97F4A7C15",
            "uniform": "(next_u64() >> 11) * 2^-53",
        },
        "momentum": M.MOMENTUM,
        "weight_decay": M.WEIGHT_DECAY,
        "models": {},
    }

    for name in names:
        model = M.MODELS[name]
        t0 = time.time()
        entries = []
        for entry in ENTRIES_FOR_MODEL[name]:
            for batch in M.entry_batches(model, entry):
                lowered, specs = lower_entry(model, entry, batch)
                text = to_hlo_text(lowered)
                fname = f"{name}_{entry}_b{batch}.hlo.txt"
                with open(os.path.join(out_dir, fname), "w") as f:
                    f.write(text)
                entries.append(
                    {
                        "entry": entry,
                        "batch": batch,
                        "file": fname,
                        "args": [spec_json(s) for s in specs],
                    }
                )
                if not args.quiet:
                    print(f"  {fname}: {len(text)} chars, {len(specs)} args")
        manifest["models"][name] = {
            "feature_dim": model.feature_dim,
            "num_classes": model.num_classes,
            "batch": model.batch,
            "eval_batch": model.eval_batch,
            "presample": list(model.presample),
            "params": [
                {"name": p.name, "shape": list(p.shape), "init": p.init}
                for p in model.params
            ],
            "entries": entries,
            "selfcheck": build_selfcheck(model),
        }
        if not args.quiet:
            print(f"{name}: done in {time.time() - t0:.1f}s")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()

"""Pure-jnp oracles for the L1 kernels.

These are the correctness ground truth: slow, obvious, no tiling, no fusion.
pytest (and the hypothesis sweeps in ``python/tests``) assert the Pallas
kernels match these to tight tolerances across shapes/dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent_loss(z, y):
    """Per-sample softmax cross-entropy loss. f32[b]."""
    z = z.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    z_true = jnp.take_along_axis(z, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - z_true


def upper_bound_scores(z, y):
    """Eq.-20 score: || softmax(z_i) - onehot(y_i) ||_2. f32[b]."""
    z = z.astype(jnp.float32)
    p = jax.nn.softmax(z, axis=-1)
    onehot = jax.nn.one_hot(y, z.shape[-1], dtype=jnp.float32)
    return jnp.linalg.norm(p - onehot, axis=-1)


def fused_loss_scores(z, y):
    """Oracle twin of kernels.last_layer.fused_loss_scores."""
    return softmax_xent_loss(z, y), upper_bound_scores(z, y)


def weighted_xent_mean(z, y, w):
    """(1/b) sum_i w_i * xent(z_i, y_i) — the loss whose d/dz the bwd kernel computes."""
    return jnp.mean(w * softmax_xent_loss(z, y))


def weighted_xent_grad(z, y, w, gbar):
    """Oracle twin of kernels.last_layer.weighted_xent_grad via autodiff."""
    g = jax.grad(lambda zz: weighted_xent_mean(zz, y, w))(z.astype(jnp.float32))
    return g * gbar[0]

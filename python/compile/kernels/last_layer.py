"""Layer-1 Pallas kernels: fused last-layer loss + importance score.

The paper's per-sample importance score (Eq. 20) is

    ghat_i = || Sigma'_L(z_i) grad_{x^(L)} L ||_2

i.e. the L2 norm of the gradient of the loss w.r.t. the *pre-activation*
outputs of the last layer. For a linear last layer feeding softmax
cross-entropy this is exactly

    ghat_i = || softmax(z_i) - onehot(y_i) ||_2

which is computable in closed form from the logits — one forward pass, no
backprop. These kernels fuse the per-sample loss and the score into a single
pass over the logits, tiled over the batch so each block lives in VMEM.

Kernels
-------
``fused_loss_scores``   (z[b,C], y[b])            -> (loss[b], ghat[b])
``weighted_xent_grad``  (z[b,C], y[b], w[b], gbar) -> dz[b,C]

The second kernel is the backward twin: d/dz of (1/b) sum_i w_i * loss_i,
scaled by the incoming cotangent ``gbar``. Together they let the training
step backprop *through* the Pallas kernel via ``jax.custom_vjp`` (see
``python/compile/model.py``), so L1 sits on both the scoring and the
training hot path.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls. The BlockSpec schedule (block rows BT over a
``grid=(ceil(b/BT),)``) is what a real TPU lowering would use; DESIGN.md
§Hardware-Adaptation and EXPERIMENTS.md §Perf estimate its VMEM/VPU
behaviour analytically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of logits per VMEM block. 3 live f32 blocks of (128, C<=128) are
# ~196 KiB — far under the 16 MiB VMEM budget; 128 keeps the VPU lanes
# (8x128) fully occupied on the class axis for C >= 128 and amortizes the
# grid overhead for small C.
DEFAULT_BLOCK_ROWS = 128


def _num_blocks(b: int, bt: int) -> int:
    return (b + bt - 1) // bt


# ---------------------------------------------------------------------------
# fused_loss_scores
# ---------------------------------------------------------------------------


def _fused_loss_scores_kernel(z_ref, y_ref, loss_ref, g_ref, *, num_classes):
    """One (BT, C) block: per-row softmax-xent loss and score.

    loss_i = logsumexp(z_i) - z_i[y_i]
    g_i    = || softmax(z_i) - onehot(y_i) ||_2
    """
    z = z_ref[...].astype(jnp.float32)  # (BT, C)
    y = y_ref[...]  # (BT,) int32

    # Numerically stable logsumexp per row.
    zmax = jnp.max(z, axis=-1, keepdims=True)  # (BT, 1)
    ez = jnp.exp(z - zmax)  # (BT, C)
    sez = jnp.sum(ez, axis=-1, keepdims=True)  # (BT, 1)
    lse = jnp.log(sez) + zmax  # (BT, 1)

    # Gather z[i, y_i] without dynamic gather: onehot via iota comparison
    # (TPU-friendly; gathers lower poorly in Mosaic).
    classes = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)  # (BT, C)
    onehot = (classes == y[:, None]).astype(jnp.float32)  # (BT, C)
    z_true = jnp.sum(z * onehot, axis=-1, keepdims=True)  # (BT, 1)

    loss = lse - z_true  # (BT, 1)

    p = ez / sez  # softmax, (BT, C)
    d = p - onehot
    g = jnp.sqrt(jnp.sum(d * d, axis=-1, keepdims=True))  # (BT, 1)

    loss_ref[...] = loss[:, 0]
    g_ref[...] = g[:, 0]


def fused_loss_scores(z, y, *, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Per-sample loss and Eq.-20 upper-bound score from logits.

    Args:
      z: f32[b, C] logits (pre-activation outputs of the last layer).
      y: i32[b] integer class labels.
      block_rows: batch tile height (VMEM block rows).

    Returns:
      (loss, ghat): two f32[b] vectors.
    """
    b, num_classes = z.shape
    bt = min(block_rows, b)
    grid = (_num_blocks(b, bt),)
    kernel = functools.partial(_fused_loss_scores_kernel, num_classes=num_classes)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, num_classes), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(z, y)


# ---------------------------------------------------------------------------
# weighted_xent_grad
# ---------------------------------------------------------------------------


def _weighted_xent_grad_kernel(z_ref, y_ref, w_ref, gbar_ref, dz_ref, *, inv_b):
    """One (BT, C) block of d/dz [ (1/b) sum_i w_i loss_i ] * gbar."""
    z = z_ref[...].astype(jnp.float32)
    y = y_ref[...]
    w = w_ref[...].astype(jnp.float32)
    gbar = gbar_ref[0]

    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    p = ez / jnp.sum(ez, axis=-1, keepdims=True)

    classes = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    onehot = (classes == y[:, None]).astype(jnp.float32)

    scale = (w * (inv_b * gbar))[:, None]  # (BT, 1)
    dz_ref[...] = (p - onehot) * scale


def weighted_xent_grad(z, y, w, gbar, *, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Gradient of the re-weighted mean cross-entropy w.r.t. logits.

    Computes ``dz[i, :] = w[i]/b * (softmax(z_i) - onehot(y_i)) * gbar`` —
    the VJP of ``(1/b) * sum_i w_i * xent(z_i, y_i)`` with scalar cotangent
    ``gbar``.

    Args:
      z: f32[b, C] logits.
      y: i32[b] labels.
      w: f32[b] per-sample importance weights (1 for uniform sampling).
      gbar: f32[1] cotangent of the scalar loss.

    Returns:
      dz: f32[b, C].
    """
    b, num_classes = z.shape
    bt = min(block_rows, b)
    grid = (_num_blocks(b, bt),)
    kernel = functools.partial(_weighted_xent_grad_kernel, inv_b=1.0 / b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, num_classes), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            # gbar is a broadcast scalar: every block sees the same (1,) slab.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, num_classes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, num_classes), jnp.float32),
        interpret=True,
    )(z, y, w, gbar)
